"""Executors: run one :class:`~repro.core.schedule.RoundSchedule` on params.

Two data planes consume the same schedule object:

* :class:`HostExecutor` — the reference semantics.  One parameter pytree per
  client slot, local updates through ``repro.fl.client`` /
  ``repro.fl.fedprox`` exactly as the original per-strategy loops did
  (same per-client batch draws, same jitted step, same aggregation order),
  so refactored strategies reproduce their pre-schedule trajectories.

* :class:`FleetExecutor` — the client-stacked fast path.  All slots live on
  one pytree with a leading client axis; a local "session" (one epoch of
  batches, momentum restarted, per-slot gradient clipping) is a jitted
  ``vmap`` over that axis, a diffusion hop is
  :func:`~repro.distributed.fedshard.diffuse_params`, STC hops use
  :func:`~repro.distributed.fedshard.masked_stc_compress`, and Eq.-11
  aggregation is one weighted ``tensordot``.  Clients with shorter epochs
  are padded and masked out per step, so the math per client matches the
  host loop; the win is dispatch count — O(max-epoch) jitted calls per op
  instead of O(Σ client batches) — which is what lets sweeps scale past
  paper-sized fleets.

* :class:`ShardedFleetExecutor` — the large-N plane.  The stacked pytree's
  leading client axis is *sharded* over a 1-D ``("clients",)`` mesh
  (:func:`repro.launch.mesh.make_clients_mesh`,
  :func:`repro.distributed.sharding.client_stacked_specs`) with
  ``shard_map``: local sessions run client-parallel across devices with the
  per-shard block further **microbatched** (``lax.map`` over chunks of
  ``FLConfig.shard_microbatch`` clients) so N=256–1024 fleets fit in
  memory; a :class:`~repro.core.schedule.PermuteOp` becomes a sharded
  permutation collective (static routing tables + per-shift
  ``lax.ppermute``); a :class:`~repro.core.schedule.MixOp` is a
  ``psum_scatter``; Eq.-11 aggregation is a masked ``psum`` over the client
  axis.  On a 1-device mesh it degenerates to the fleet program.

Ledger charging lives in none of them: :func:`~repro.core.schedule
.charge_schedule` replays the schedule's wire events, so all executors
report identical communication metrics by construction.
"""
from __future__ import annotations

import copy
import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import aggregation as agg
from repro.core.schedule import MixOp, PermuteOp, RoundSchedule, TrainOp
from repro.distributed.fedshard import diffuse_params, masked_stc_compress
from repro.distributed.sharding import CLIENT_AXIS
from repro.fl.compression import stc_compress
from repro.fl.schedulers import PROX_STRATEGIES
from repro.kernels import ops as kernel_ops
from repro.train import optimizer as opt_lib

Params = Any

__all__ = ["HostExecutor", "FleetExecutor", "ShardedFleetExecutor",
           "make_executor", "EXECUTORS"]

EXECUTORS = ("host", "fleet", "sharded")


def _tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


def _tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


class HostExecutor:
    """Per-slot pytree-list execution — the bit-for-bit reference path."""

    def __init__(self, local_update: Callable,
                 client_batches: Sequence[Callable], cfg):
        self.local_update = local_update
        self.client_batches = client_batches
        self.cfg = cfg

    def _train(self, slots: list, mask: np.ndarray) -> None:
        for c in np.flatnonzero(mask):
            slots[c], _ = self.local_update(
                slots[c], self.client_batches[c](), self.cfg.lr)

    # ------------------------------------------------- round-state capture
    # Persistent strategies (gossip, tthf) carry per-slot state across
    # communication rounds; the resume seam (repro.fl.resume) round-trips it
    # through these three hooks so a checkpoint taken under any executor
    # restores onto the same executor bit-identically.

    def capture_slots(self, slots: list | None):
        """Host-resident copy of the persistent slot state (or ``None``)."""
        return None if slots is None else jax.device_get(slots)

    def slots_like(self, global_params: Params, num_slots: int):
        """Shape/dtype template matching :meth:`capture_slots` output."""
        leaf = lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)  # noqa: E731
        return [jax.tree.map(leaf, global_params) for _ in range(num_slots)]

    def num_slots_of(self, saved) -> int:
        """Slot count of a :meth:`capture_slots` capture (host: outer list).

        The executor is authoritative here — the capture's pytree structure
        alone is ambiguous (a model whose params are themselves a list looks
        like a host slot-list)."""
        return len(saved)

    def adopt_slots(self, saved):
        """Executor-native placement of a captured slot tree."""
        return saved

    def run_round(self, sched: RoundSchedule, global_params: Params,
                  slots: list | None) -> tuple[Params, list | None]:
        c_slots = sched.num_slots
        if not sched.persistent or slots is None:
            slots = [copy.deepcopy(global_params) for _ in range(c_slots)]
        ref = global_params
        for op in sched.ops:
            if isinstance(op, TrainOp):
                self._train(slots, op.train_mask)
            elif isinstance(op, PermuteOp):
                if op.compress:
                    for s in np.flatnonzero(op.compress_src_mask()):
                        delta = stc_compress(_tree_sub(slots[s], ref),
                                             sched.stc_sparsity)
                        slots[s] = _tree_add(ref, delta)
                slots = [slots[int(op.src_of_dst[c])] for c in range(c_slots)]
                self._train(slots, op.train_mask)
            elif isinstance(op, MixOp):
                for members, weights in op.groups:
                    avg = agg.fedavg([slots[i] for i in members],
                                     list(weights))
                    for i in members:
                        slots[i] = avg
            else:
                raise TypeError(f"unknown op {type(op).__name__}")
        weights = [w for _, w in sched.agg]
        if sched.agg_mode == "stc_delta":
            deltas = [stc_compress(_tree_sub(slots[s], ref),
                                   sched.stc_sparsity) for s, _ in sched.agg]
            new_global = _tree_add(ref, agg.fedavg(deltas, weights))
        else:
            new_global = agg.fedavg([slots[s] for s, _ in sched.agg], weights)
        return new_global, (slots if sched.persistent else None)


class FleetExecutor:
    """Client-stacked execution: one pytree, leading client axis, jitted."""

    def __init__(self, loss_fn: Callable,
                 client_batches: Sequence[Callable], cfg,
                 clip: float | None = 10.0):
        self.loss_fn = loss_fn
        self.client_batches = client_batches
        self.cfg = cfg
        self.prox = cfg.strategy in PROX_STRATEGIES
        opt = opt_lib.sgd(momentum=cfg.momentum)
        mu = float(cfg.prox_mu)

        def one(p, mom, batch, active, anchor):
            def obj(q):
                loss = loss_fn(q, batch)
                if self.prox:
                    prox = sum(jnp.sum((a.astype(jnp.float32)
                                        - b.astype(jnp.float32)) ** 2)
                               for a, b in zip(jax.tree.leaves(q),
                                               jax.tree.leaves(anchor)))
                    loss = loss + 0.5 * mu * prox
                return loss

            loss, grads = jax.value_and_grad(obj)(p)
            if clip is not None:
                grads, _ = opt_lib.clip_by_global_norm(grads, clip)
            updates, new_state = opt.update(grads, {"mu": mom}, p, cfg.lr)
            p2 = opt_lib.apply_updates(p, updates)
            sel = functools.partial(jnp.where, active)
            return (jax.tree.map(sel, p2, p),
                    jax.tree.map(sel, new_state["mu"], mom), loss)

        self._one = one          # per-client step; ShardedFleetExecutor remaps
        self._step = jax.jit(jax.vmap(one))

    # ---------------------------------------------------------------- batches

    def _draw_session(self, mask: np.ndarray):
        """Draw one local epoch per *masked* slot (preserving each client's
        host-side batch stream), pad to the longest epoch, stack per step.

        Returns ``(steps, actives)``: per padded step, a client-stacked batch
        dict and the (C,) bool mask of slots genuinely training that step.
        """
        per_slot = [list(self.client_batches[c]()) if mask[c] else []
                    for c in range(len(mask))]
        nb = max((len(b) for b in per_slot), default=0)
        if nb == 0:
            return [], []
        template = jax.tree.map(
            np.zeros_like, next(b[0] for b in per_slot if b))
        steps, actives = [], []
        for k in range(nb):
            rows = [b[k] if k < len(b) else template for b in per_slot]
            steps.append(jax.tree.map(
                lambda *xs: jnp.asarray(np.stack(xs)), *rows))
            actives.append(jnp.asarray(
                np.array([k < len(b) for b in per_slot])))
        return steps, actives

    def _session(self, params: Params, mask: np.ndarray) -> Params:
        """One local-update session at every masked slot (vmapped epoch)."""
        if not mask.any():
            return params
        steps, actives = self._draw_session(mask)
        mom = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        anchor = params      # prox anchor = the received model (host default)
        for batch, active in zip(steps, actives):
            params, mom, _ = self._step(params, mom, batch, active, anchor)
        return params

    # ------------------------------------------------- round-state capture

    def capture_slots(self, slots: Params | None):
        return None if slots is None else jax.device_get(slots)

    def slots_like(self, global_params: Params, num_slots: int):
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((num_slots,) + x.shape, x.dtype),
            global_params)

    def num_slots_of(self, saved) -> int:
        """Slot count of a capture (fleet: the stacked leading axis)."""
        return int(jax.tree.leaves(saved)[0].shape[0])

    def adopt_slots(self, saved):
        return jax.tree.map(jnp.asarray, saved)

    # ----------------------------------------------- overridable primitives
    # One round structure (run_round below), two placements:
    # ShardedFleetExecutor overrides exactly these five hooks with its
    # collective twins, so a new op kind or agg mode is added in one place.

    def _broadcast(self, global_params: Params, num_slots: int) -> Params:
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (num_slots,) + x.shape),
            global_params)

    def _permute(self, params: Params, op: PermuteOp) -> Params:
        return diffuse_params(params, jnp.asarray(op.src_of_dst))

    def _mix(self, params: Params, op: MixOp, num_slots: int) -> Params:
        # Eq. (10) through the kernel data plane: the fused single-HBM-pass
        # Pallas kernel on TPU / under REPRO_KERNELS_IMPL, the per-leaf
        # einsum chain on the XLA reference path.
        w = jnp.asarray(op.matrix(num_slots), jnp.float32)
        return kernel_ops.mix_aggregate_tree(params, w)

    def _masked_stc(self, params: Params, ref: Params, mask: np.ndarray,
                    sparsity: float) -> Params:
        return masked_stc_compress(params, ref, jnp.asarray(mask), sparsity)

    def _aggregate(self, payload: Params, w: jax.Array) -> Params:
        # Eq. (11): aggregation is the same kernel with one output row.
        return kernel_ops.mix_aggregate_tree(
            payload, w.astype(jnp.float32).reshape(1, -1), collapse=True)

    # ------------------------------------------------------------------ round

    def run_round(self, sched: RoundSchedule, global_params: Params,
                  slots: Params | None) -> tuple[Params, Params | None]:
        c_slots = sched.num_slots
        if sched.persistent and slots is not None:
            params = slots
        else:
            params = self._broadcast(global_params, c_slots)
        ref = global_params
        for op in sched.ops:
            if isinstance(op, TrainOp):
                params = self._session(params, op.train_mask)
            elif isinstance(op, PermuteOp):
                if op.compress:
                    params = self._masked_stc(params, ref,
                                              op.compress_src_mask(),
                                              sched.stc_sparsity)
                params = self._permute(params, op)
                params = self._session(params, op.train_mask)
            elif isinstance(op, MixOp):
                params = self._mix(params, op, c_slots)
            else:
                raise TypeError(f"unknown op {type(op).__name__}")
        wvec = sched.slot_weights()
        w = jnp.asarray((wvec / wvec.sum()).astype(np.float32))
        if sched.agg_mode == "stc_delta":
            payload = self._masked_stc(params, ref, wvec > 0,
                                       sched.stc_sparsity)
        else:
            payload = params
        new_global = self._aggregate(payload, w)
        return new_global, (params if sched.persistent else None)


def _permutation_tables(src_of_dst: np.ndarray, num_shards: int
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Static routing tables for a slot bijection on a ``num_shards`` mesh.

    The global permutation ``new[c] = old[src_of_dst[c]]`` is decomposed into
    ``num_shards`` ring shifts: rows moving from shard ``s`` to shard
    ``(s + shift) % K`` travel together in one ``ppermute`` step.  Returns

    * ``send[s, shift, i]`` — local row index the *source* shard ``s`` packs
      at buffer position ``i`` for shift ``shift`` (0-padded), and
    * ``recv[d, shift, i]`` — local row index where the *destination* shard
      ``d`` scatters buffer position ``i`` (padded with ``n_local``, a trash
      row dropped after the scatter).

    Packing order ``i`` is shared between the two tables because a
    ``(shift, src)`` pair determines the destination shard uniquely.  The
    tables are data, not code: one compiled collective serves every
    permutation of a round without retracing.
    """
    perm = np.asarray(src_of_dst, np.int64)
    c = perm.shape[0]
    k = num_shards
    assert c % k == 0, (c, k)
    nl = c // k
    send = np.zeros((k, k, nl), np.int32)
    recv = np.full((k, k, nl), nl, np.int32)
    fill = np.zeros((k, k), np.int32)
    for dst in range(c):
        src = int(perm[dst])
        s, d = src // nl, dst // nl
        shift = (d - s) % k
        i = int(fill[shift, s])
        fill[shift, s] = i + 1
        send[s, shift, i] = src % nl
        recv[d, shift, i] = dst % nl
    return send, recv


class ShardedFleetExecutor(FleetExecutor):
    """Client-sharded execution over a ``("clients",)`` mesh axis.

    Same math as :class:`FleetExecutor` (it reuses the per-client step and
    the host-side batch streams verbatim); the difference is placement: the
    leading client axis of every pytree leaf lives sharded across the mesh,
    sessions are ``shard_map``-ped so each device trains only its block of
    clients — microbatched in chunks of ``FLConfig.shard_microbatch`` so
    device memory is O(microbatch), not O(N) — and cross-client ops are
    explicit collectives (``ppermute`` hops, ``psum_scatter`` mixes, masked
    ``psum`` aggregation).
    """

    def __init__(self, loss_fn: Callable,
                 client_batches: Sequence[Callable], cfg,
                 clip: float | None = 10.0, mesh=None):
        super().__init__(loss_fn, client_batches, cfg, clip)
        from repro.launch.mesh import make_clients_mesh
        c = cfg.num_clients
        self.mesh = mesh if mesh is not None else make_clients_mesh(c)
        self.k = int(self.mesh.shape[CLIENT_AXIS])
        assert c % self.k == 0, (c, self.k)
        self.nl = c // self.k
        mb_cap = max(1, int(getattr(cfg, "shard_microbatch", 32)))
        self.mb = max(b for b in range(1, min(mb_cap, self.nl) + 1)
                      if self.nl % b == 0)
        self.nchunks = self.nl // self.mb
        self._stc_cache: dict = {}
        self._build()

    # ------------------------------------------------------- compiled planes

    def _shmap(self, f, in_specs, out_specs):
        return jax.jit(shard_map(f, mesh=self.mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False))

    def _build(self) -> None:
        pc = P(CLIENT_AXIS)
        k, nl, nchunks, mb = self.k, self.nl, self.nchunks, self.mb
        vstep = jax.vmap(self._one)

        def chunked_session_step(p, mom, batch, active, anchor):
            # Local block of nl clients, trained in nchunks microbatches so
            # activations/grads are O(mb) per device, not O(N).
            args = (p, mom, batch, active, anchor)
            if nchunks == 1:
                return vstep(*args)
            split = jax.tree.map(
                lambda x: x.reshape((nchunks, mb) + x.shape[1:]), args)
            out = jax.lax.map(lambda a: vstep(*a), split)
            return jax.tree.map(
                lambda x: x.reshape((-1,) + x.shape[2:]), out)

        # Overrides FleetExecutor._step: _session() is inherited unchanged.
        self._step = self._shmap(chunked_session_step,
                                 in_specs=(pc, pc, pc, pc, pc),
                                 out_specs=(pc, pc, pc))

        def permute_leaf(x, send, recv):
            out = jnp.zeros((nl + 1,) + x.shape[1:], x.dtype)
            for shift in range(k):
                buf = jnp.take(x, send[shift], axis=0)
                if shift:
                    buf = jax.lax.ppermute(
                        buf, CLIENT_AXIS,
                        [(s, (s + shift) % k) for s in range(k)])
                out = out.at[recv[shift]].set(buf)
            return out[:nl]

        def permute_tree(params, send, recv):
            send, recv = send[0], recv[0]      # (1, k, nl) local -> (k, nl)
            return jax.tree.map(
                lambda x: permute_leaf(x, send, recv), params)

        self._sh_permute = self._shmap(permute_tree,
                                       in_specs=(pc, pc, pc), out_specs=pc)

        def mix_tree(params, wt_local):
            # wt_local: this shard's (nl, C) block of Wᵀ — the kernel data
            # plane computes the partial products over local source slots
            # ((C, ...) fp32 per leaf: partials stay fp32 across the
            # collective), then psum_scatter reduces them back to owners.
            part = kernel_ops.mix_aggregate_tree(params, wt_local.T,
                                                 keep_float32=True)

            def scatter(x, orig):
                out = jax.lax.psum_scatter(x, CLIENT_AXIS,
                                           scatter_dimension=0, tiled=True)
                return out.astype(orig.dtype)
            return jax.tree.map(scatter, part, params)

        self._sh_mix = self._shmap(mix_tree, in_specs=(pc, pc), out_specs=pc)

        def agg_tree(payload, w_local):
            # Eq. (11) as a masked psum: dropped/churned slots carry zero
            # weight, so their shard contributes nothing to the reduction.
            part = kernel_ops.mix_aggregate_tree(
                payload, w_local.reshape(1, -1), collapse=True,
                keep_float32=True)

            def reduce(x, orig):
                return jax.lax.psum(x, CLIENT_AXIS).astype(orig.dtype)
            return jax.tree.map(reduce, part, payload)

        self._sh_agg = self._shmap(agg_tree, in_specs=(pc, pc), out_specs=P())

        def bcast_tree(g):
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x, (nl,) + x.shape), g)

        self._sh_bcast = self._shmap(bcast_tree, in_specs=P(), out_specs=pc)

    def _sh_stc(self, sparsity: float):
        fn = self._stc_cache.get(sparsity)
        if fn is None:
            def stc_tree(params, ref, mask):
                return masked_stc_compress(params, ref, mask, sparsity)
            fn = self._shmap(stc_tree, in_specs=(P(CLIENT_AXIS), P(),
                                                 P(CLIENT_AXIS)),
                             out_specs=P(CLIENT_AXIS))
            self._stc_cache[sparsity] = fn
        return fn

    # ------------------------- primitive overrides (round loop inherited)

    def adopt_slots(self, saved):
        # Restored slot state must land client-sharded, not replicated —
        # the shard_map planes expect the leading axis on the mesh.
        sh = jax.sharding.NamedSharding(self.mesh, P(CLIENT_AXIS))
        return jax.tree.map(
            lambda x: jax.device_put(jnp.asarray(x), sh), saved)

    def _broadcast(self, global_params: Params, num_slots: int) -> Params:
        return self._sh_bcast(global_params)

    def _permute(self, params: Params, op: PermuteOp) -> Params:
        send, recv = _permutation_tables(op.src_of_dst, self.k)
        return self._sh_permute(params, jnp.asarray(send),
                                jnp.asarray(recv))

    def _mix(self, params: Params, op: MixOp, num_slots: int) -> Params:
        wt = np.ascontiguousarray(op.matrix(num_slots).T)
        return self._sh_mix(params, jnp.asarray(wt))

    def _masked_stc(self, params: Params, ref: Params, mask: np.ndarray,
                    sparsity: float) -> Params:
        return self._sh_stc(sparsity)(params, ref, jnp.asarray(mask))

    def _aggregate(self, payload: Params, w: jax.Array) -> Params:
        return self._sh_agg(payload, w)

    def run_round(self, sched: RoundSchedule, global_params: Params,
                  slots: Params | None) -> tuple[Params, Params | None]:
        # The mesh/tables were built for cfg.num_clients slots.
        assert sched.num_slots == self.cfg.num_clients, \
            (sched.num_slots, self.cfg.num_clients)
        return super().run_round(sched, global_params, slots)


def make_executor(name: str, loss_fn: Callable, local_update: Callable,
                  client_batches: Sequence[Callable], cfg):
    """Build the executor selected by ``FLConfig.executor``."""
    if name == "host":
        return HostExecutor(local_update, client_batches, cfg)
    if name == "fleet":
        return FleetExecutor(loss_fn, client_batches, cfg)
    if name == "sharded":
        return ShardedFleetExecutor(loss_fn, client_batches, cfg)
    raise ValueError(f"unknown executor {name!r}; expected one of "
                     f"{EXECUTORS}")
