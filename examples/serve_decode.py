"""Serve a small model with batched requests (KV-cache decode loop).

    PYTHONPATH=src python examples/serve_decode.py
"""
import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3_0_6b",
     "--smoke", "--batch", "4", "--context", "32", "--new-tokens", "16"],
    check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                     "HOME": "/root"})
