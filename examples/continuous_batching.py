"""Continuous-batching serving: more requests than KV slots, ragged
positions, greedy-consistent outputs.

    PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serving import Request, SamplerConfig, ServingEngine


def main():
    cfg = get_smoke_config("qwen3_0_6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, num_slots=4, max_seq=64,
                           sampler=SamplerConfig(temperature=0.8, top_k=40))
    rng = np.random.default_rng(0)
    n_requests = 10
    for uid in range(n_requests):
        engine.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=rng.integers(4, 12)).astype(np.int32),
            max_new_tokens=8))
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    total_new = sum(len(r.output) for r in done)
    print(f"{len(done)} requests on 4 slots in {engine.steps} engine steps "
          f"({dt:.1f}s, {total_new / dt:.1f} gen tok/s)")
    for r in sorted(done, key=lambda r: r.uid)[:4]:
        print(f"  req {r.uid}: prompt_len={len(r.prompt)} -> {r.output}")


if __name__ == "__main__":
    main()
