"""Quickstart: FedDif vs FedAvg on a Dirichlet-non-IID synthetic task.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's headline claim in miniature: under non-IID client
data, diffusing models across clients between aggregations (FedDif) beats
plain FedAvg at the same number of communication rounds, at the price of
extra D2D sub-frames (Table II trade-off).
"""
from repro.fl import ExperimentSpec, FLConfig, run_experiment


def main():
    for strategy in ("fedavg", "feddif"):
        spec = ExperimentSpec(
            task="fcn", alpha=0.3,            # fairly skewed non-IID
            num_samples=6000,
            fl=FLConfig(strategy=strategy, rounds=8, num_clients=8,
                        num_models=8, epsilon=0.04, gamma_min=1.0, seed=0))
        res = run_experiment(spec)
        print(f"{strategy:8s} peak_acc={max(res.accuracy):.3f} "
              f"acc_by_round={[round(a, 3) for a in res.accuracy]}")
        print(f"{'':8s} subframes={res.ledger.subframes} "
              f"transmitted_models={res.ledger.transmitted_models} "
              f"mean_diffusion_rounds={sum(res.diffusion_rounds)/8:.1f}")


if __name__ == "__main__":
    main()
