"""End-to-end driver: federated LM training with FedDif on non-IID corpus
shards (reduced smollm family config on CPU; drop --smoke on real pods).

    PYTHONPATH=src python examples/fl_lm_training.py
"""
import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.train", "--arch", "smollm_360m",
     "--smoke", "--rounds", "4", "--clients", "4", "--steps-per-round", "4",
     "--seq-len", "64", "--batch", "4"],
    check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                     "HOME": "/root"})
