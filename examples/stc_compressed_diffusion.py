"""Beyond-paper composition: FedDif with STC-compressed D2D hops.

The paper notes (Sec. VI-E) that STC "can obtain synergy with FedDif".
This example maps that trade-off: diffusion hops ship sparse-ternary
DELTAS against the round-start global model instead of dense fp32 weights.
Because compression is applied per hop (~9 hops/round vs STC's one uplink
per round), aggressive sparsity compounds — the sweep shows the
accuracy-vs-bits frontier.

    PYTHONPATH=src python examples/stc_compressed_diffusion.py
"""
from repro.fl import ExperimentSpec, FLConfig, run_experiment


def run(strategy, sparsity=0.0):
    spec = ExperimentSpec(
        task="fcn", alpha=0.3, num_samples=6000,
        fl=FLConfig(strategy=strategy, rounds=6, num_clients=8, num_models=8,
                    stc_sparsity=sparsity, seed=0))
    return run_experiment(spec)


def main():
    base = run("feddif")
    print(f"feddif (dense fp32 hops): peak_acc={max(base.accuracy):.3f} "
          f"d2d_bits={base.ledger.transmitted_bits:.2e}")
    for sp in (0.02, 0.1, 0.2):
        res = run("feddif_stc", sp)
        ratio = base.ledger.transmitted_bits / res.ledger.transmitted_bits
        print(f"feddif_stc sparsity={sp:4}: peak_acc={max(res.accuracy):.3f} "
              f"d2d_bits={res.ledger.transmitted_bits:.2e} ({ratio:.1f}x fewer)")


if __name__ == "__main__":
    main()
