"""Inspect one communication round of the FedDif auction (Algorithm 1/2).

    PYTHONPATH=src python examples/auction_trace.py

Prints, per diffusion round: the winner matching, per-hop IID-distance
decrement (the bid), spectral efficiency of the scheduled link, and the
bandwidth cost — the control-plane view of the paper's Fig. 1.
"""
import numpy as np

from repro.core import DiffusionPlanner, DiffusionState

N, M, C = 8, 8, 10
rng = np.random.default_rng(0)
dsi = rng.dirichlet(np.ones(C) * 0.3, N).astype(np.float32)
sizes = rng.integers(200, 800, N).astype(np.float64)

state = DiffusionState.init(M, N, C)
for m in range(M):
    state.record_training(m, m, dsi[m], float(sizes[m]))
print("initial IID distances:", np.round(state.iid_distances(), 3))

planner = DiffusionPlanner(epsilon=0.04)
plan = planner.plan_communication_round(state, dsi, sizes, rng)
for k in range(plan.num_rounds):
    hops = plan.hops_in_round(k)
    print(f"\ndiffusion round {k}: {len(hops)} scheduled hops "
          f"(efficiency {plan.efficiency_per_round[k]:.3e})")
    for h in hops:
        print(f"  model {h.model}: PUE {h.src} -> {h.dst}  "
              f"bid(dIID)={h.decrement:.4f}  gamma={h.gamma:.2f} b/s/Hz  "
              f"bandwidth={h.bandwidth:.3e}")
print("\nfinal IID distances:", np.round(plan.final_iid_distance, 3))
